"""Population-plane artifact (DESIGN.md §15): deadline + over-selection
on an unreliable client fleet, served from the lazy registry.

Three FOMAML cells under one shared seed, split, and task stream, with
clients materialized on demand from a bounded-cache `ClientRegistry`
through the fault-tolerant worker pool:

  * ``clean``       — every selected client arrives (no arrival model);
  * ``overselect``  — heavy injected per-round failures (>=20%; default
                      65%) plus a tight latency deadline, countered by
                      over-selection: the server samples
                      m·(1+over_select) candidates and aggregates the
                      first m arrivals;
  * ``baseline``    — the SAME failures and deadline with over-selection
                      off: collapsed cohorts and dead (all-failed,
                      guard-skipped) rounds, renormalized by
                      `masked_mean`.

The committed artifact (``results/experiments/population_sent140.json``)
is the PR-7 acceptance evidence. The over-selected run restores cohort
fill and converges within the clean run's noise band, while the
no-over-selection baseline *stalls*: a large fraction of rounds make
zero progress (all candidates fail → guard skip) and the rest run at a
small fraction of the cohort, so its effective client-updates collapse
— the production failure mode over-selection exists to prevent. The
comm block shows what over-selection costs: download charges ALL
selected candidates, upload only the arrived.

  # full artifact (~7 min CPU):
  PYTHONPATH=src python examples/population_scale.py

  # CI smoke (tiny rounds/pool, smoke outdir):
  PYTHONPATH=src python examples/population_scale.py --dry-run
"""
import argparse
import json
import os

import jax

from repro.core import classification_loss, make_algorithm
from repro.federated.experiment import DATASETS
from repro.federated.population import UnreliabilityConfig
from repro.federated.server import FederatedTrainer, evaluate_meta
from repro.optim import adam


def run_cell(name, *, su, model, train, val, test, args, unreliability,
             over_select, round_deadline):
    loss_fn, eval_fn = classification_loss(model.apply)
    algo = make_algorithm("fomaml", loss_fn, eval_fn,
                          inner_lr=args.inner_lr)
    m = args.clients_per_round
    tr = FederatedTrainer(
        algo, adam(args.outer_lr), train, m,
        support_frac=args.support_frac, support_size=args.support_size,
        query_size=args.query_size, seed=args.seed, packed=True,
        unreliability=unreliability, over_select=over_select,
        round_deadline=round_deadline, pool_workers=args.pool_workers)
    state = tr.init(jax.random.PRNGKey(args.seed), model.init)
    state = tr.run(state, args.rounds, eval_every=args.eval_every,
                   eval_clients=val)
    test_acc, _, test_loss = evaluate_meta(
        algo, tr.phi_tree(state), test, support_frac=args.support_frac,
        support_size=args.support_size, query_size=args.query_size,
        seed=args.seed, evaluator=tr.evaluator())
    curve = [(r["round"], r["eval_acc"]) for r in tr.history
             if "eval_acc" in r]
    arrived = [r["arrived"] for r in tr.history if "arrived" in r]
    skipped = int(sum(r.get("skipped", 0.0) for r in tr.history))
    # per-round meta query loss: the continuous progress signal (NaN on
    # guard-skipped rounds — a dead round contributes no measurement)
    loss_curve = [(r["round"], r["query_loss"]) for r in tr.history
                  if "query_loss" in r
                  and r["query_loss"] == r["query_loss"]]
    mean_arr = (sum(arrived) / len(arrived)) if arrived else float(m)
    return {
        "cell": name, "over_select": over_select,
        "round_deadline": round_deadline,
        "fail_rate": unreliability.fail_rate if unreliability else 0.0,
        "final_val_acc": curve[-1][1] if curve else None,
        "best_val_acc": max((a for _, a in curve), default=None),
        "final_test_acc": test_acc, "final_test_loss": test_loss,
        "eval_curve": curve,
        "loss_curve": loss_curve,
        "rounds": args.rounds,
        "skipped_rounds": skipped,
        "dead_round_frac": skipped / args.rounds,
        "mean_arrived": mean_arr,
        "cohort_fill": mean_arr / m,
        # total effective client-updates that reached the aggregator
        "client_updates": int(round(sum(arrived))) if arrived
        else m * args.rounds,
        "comm": tr.comm.summary(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sent140",
                    choices=["sent140", "femnist"])
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--eval-every", type=int, default=3)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--clients-per-round", type=int, default=0,
                    help="0 = the dataset registry default")
    ap.add_argument("--support-frac", type=float, default=0.2)
    ap.add_argument("--support-size", type=int, default=16)
    ap.add_argument("--query-size", type=int, default=16)
    ap.add_argument("--inner-lr", type=float, default=0.0,
                    help="0 = the dataset registry default")
    ap.add_argument("--outer-lr", type=float, default=0.0,
                    help="0 = the dataset registry default")
    ap.add_argument("--fail-rate", type=float, default=0.65,
                    help="per-(client, round) transient failure "
                         "probability (acceptance: >= 0.2)")
    ap.add_argument("--round-deadline", type=float, default=0.75,
                    help="latency cutoff; median client latency is 1.0, "
                         "so this only admits the fast tail")
    ap.add_argument("--over-select", type=float, default=4.0,
                    help="candidate surplus fraction of the treated "
                         "cell: m·(1+x) candidates per round")
    ap.add_argument("--cache-clients", type=int, default=32,
                    help="registry LRU cap (bounded-memory serving)")
    ap.add_argument("--pool-workers", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--outdir", default="results/experiments")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny rounds/pool for CI smoke")
    args = ap.parse_args()
    if args.dry_run:
        args.rounds, args.eval_every, args.clients = 4, 2, 24
        args.cache_clients = 12
        if args.outdir == "results/experiments":
            args.outdir = "results/experiments-smoke"

    su = DATASETS[args.dataset]
    args.clients_per_round = args.clients_per_round or su["clients_per_round"]
    args.inner_lr = args.inner_lr or su["inner_lr"]
    args.outer_lr = args.outer_lr or su["outer_lr"]
    # lazy sequential registry: bit-identical to the eager dataset, but
    # clients materialize on demand into a bounded LRU cache
    reg = su["data"](args.clients, args.seed, lazy=True,
                     cache_clients=args.cache_clients)
    train, val, test = reg.split_clients(seed=args.seed)
    model = su["model"]()
    unrel = UnreliabilityConfig(fail_rate=args.fail_rate, seed=args.seed)

    cells = []
    for name, u, os_, dl in [
            ("clean", None, 0.0, None),
            ("overselect", unrel, args.over_select, args.round_deadline),
            ("baseline", unrel, 0.0, args.round_deadline)]:
        cell = run_cell(name, su=su, model=model, train=train, val=val,
                        test=test, args=args, unreliability=u,
                        over_select=os_, round_deadline=dl)
        cells.append(cell)
        print(f"[{name:10s}] final_val={cell['final_val_acc']:.4f} "
              f"fill={cell['cohort_fill']:.2f} "
              f"dead={cell['skipped_rounds']}/{args.rounds} "
              f"updates={cell['client_updates']}")

    headline = {c["cell"]: {
        "final_val_acc": c["final_val_acc"],
        "best_val_acc": c["best_val_acc"],
        "cohort_fill": round(c["cohort_fill"], 3),
        "dead_round_frac": round(c["dead_round_frac"], 3),
        "client_updates": c["client_updates"],
    } for c in cells}
    out = {
        "config": {
            "method": "fomaml",
            **{k: getattr(args, k) for k in (
                "dataset", "rounds", "eval_every", "clients",
                "clients_per_round", "support_frac", "support_size",
                "query_size", "inner_lr", "outer_lr", "fail_rate",
                "round_deadline", "over_select", "cache_clients",
                "pool_workers", "seed")},
            "regen": "PYTHONPATH=src python examples/population_scale.py",
        },
        "headline": headline,
        "registry_cache": reg.cache_stats(),
        "cells": cells,
    }
    os.makedirs(args.outdir, exist_ok=True)
    path = os.path.join(args.outdir,
                        f"population_{args.dataset}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    print(json.dumps(headline, indent=1))


if __name__ == "__main__":
    main()
