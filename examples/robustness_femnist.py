"""Robustness artifact (DESIGN.md §14): FedMeta accuracy vs client-
failure fraction under mean vs screened vs trimmed-mean aggregation.

Sweeps FOMAML on the femnist workload over a failure grid — clean,
dropout, Byzantine (sign-flip ×10), and non-finite clients at fixed
per-round fractions — for each aggregator, under one shared seed /
client split / task stream, and writes the curves + final accuracies to
``results/experiments/robustness_femnist.json``. The committed artifact
is the PR-6 acceptance evidence: robust aggregators hold accuracy at
Byzantine fractions where the plain mean demonstrably collapses
(pinned by tests/test_faults.py).

  # full artifact (~10 min CPU):
  PYTHONPATH=src python examples/robustness_femnist.py

  # CI smoke (tiny rounds/pool, smoke outdir):
  PYTHONPATH=src python examples/robustness_femnist.py --dry-run
"""
import argparse
import json
import os

import jax

from repro.core import classification_loss, make_algorithm
from repro.federated.experiment import DATASETS
from repro.federated.faults import FaultConfig
from repro.federated.server import FederatedTrainer, evaluate_meta
from repro.optim import adam

# (kind, fraction) grid: fractions of clients_per_round, one failure
# mode per cell so each curve isolates one threat model
SCENARIOS = [("clean", 0.0), ("dropout", 0.25), ("byzantine", 0.125),
             ("byzantine", 0.25), ("nonfinite", 0.125)]
AGGREGATORS = ("mean", "screen", "trimmed")


def _faults(kind: str, fraction: float, scale: float):
    if kind == "clean" or fraction == 0.0:
        return None
    return FaultConfig(**{kind: fraction}, byzantine_scale=scale)


def run_cell(kind, fraction, aggregator, *, model, train, val, test,
             args):
    loss_fn, eval_fn = classification_loss(model.apply)
    algo = make_algorithm("fomaml", loss_fn, eval_fn,
                          inner_lr=args.inner_lr)
    tr = FederatedTrainer(
        algo, adam(args.outer_lr), train, args.clients_per_round,
        support_frac=args.support_frac, support_size=args.support_size,
        query_size=args.query_size, seed=args.seed, packed=True,
        aggregator=aggregator, trim=args.trim,
        screen_factor=args.screen_factor,
        faults=_faults(kind, fraction, args.byzantine_scale))
    state = tr.init(jax.random.PRNGKey(args.seed), model.init)
    state = tr.run(state, args.rounds, eval_every=args.eval_every,
                   eval_clients=val)
    test_acc, _, test_loss = evaluate_meta(
        algo, tr.phi_tree(state), test, support_frac=args.support_frac,
        support_size=args.support_size, query_size=args.query_size,
        seed=args.seed, evaluator=tr.evaluator())
    curve = [(r["round"], r["eval_acc"]) for r in tr.history
             if "eval_acc" in r]
    return {
        "kind": kind, "fraction": fraction, "aggregator": aggregator,
        "final_test_acc": test_acc, "final_test_loss": test_loss,
        "best_val_acc": max((a for _, a in curve), default=None),
        "skipped_rounds": int(sum(r.get("skipped", 0.0)
                                  for r in tr.history)),
        "rounds": args.rounds,
        "eval_curve": curve,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--clients", type=int, default=60)
    ap.add_argument("--clients-per-round", type=int, default=8)
    ap.add_argument("--support-frac", type=float, default=0.2)
    ap.add_argument("--support-size", type=int, default=16)
    ap.add_argument("--query-size", type=int, default=16)
    ap.add_argument("--inner-lr", type=float, default=0.05,
                    help="fomaml femnist lr (registry method_overrides)")
    ap.add_argument("--outer-lr", type=float, default=1e-3)
    ap.add_argument("--trim", type=int, default=2)
    ap.add_argument("--screen-factor", type=float, default=3.0)
    ap.add_argument("--byzantine-scale", type=float, default=10.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--outdir", default="results/experiments")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny rounds/pool for CI smoke")
    args = ap.parse_args()
    if args.dry_run:
        args.rounds, args.eval_every, args.clients = 4, 2, 24
        if args.outdir == "results/experiments":
            args.outdir = "results/experiments-smoke"

    su = DATASETS["femnist"]
    ds = su["data"](args.clients, args.seed)
    train, val, test = ds.split_clients(seed=args.seed)
    model = su["model"]()

    cells = []
    for kind, fraction in SCENARIOS:
        for aggregator in AGGREGATORS:
            cell = run_cell(kind, fraction, aggregator, model=model,
                            train=train, val=val, test=test, args=args)
            cells.append(cell)
            print(f"[{kind} {fraction:.3f}] {aggregator:8s} "
                  f"test_acc={cell['final_test_acc']:.4f} "
                  f"skipped={cell['skipped_rounds']}/{args.rounds}")

    # headline: per-scenario final accuracy by aggregator — the
    # mean-collapses-robust-holds claim in one block
    headline = {}
    for kind, fraction in SCENARIOS:
        key = f"{kind}_{fraction}" if fraction else "clean"
        headline[key] = {
            c["aggregator"]: c["final_test_acc"] for c in cells
            if c["kind"] == kind and c["fraction"] == fraction}

    out = {
        "config": {
            "dataset": "femnist", "method": "fomaml",
            **{k: getattr(args, k.replace("-", "_")) for k in (
                "rounds", "eval_every", "clients", "clients_per_round",
                "support_frac", "support_size", "query_size", "inner_lr",
                "outer_lr", "trim", "screen_factor", "byzantine_scale",
                "seed")},
            "byzantine_mode": "sign_flip",
            "regen": "PYTHONPATH=src python "
                     "examples/robustness_femnist.py",
        },
        "headline": headline,
        "cells": cells,
    }
    os.makedirs(args.outdir, exist_ok=True)
    path = os.path.join(args.outdir, "robustness_femnist.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    print(json.dumps(headline, indent=1))


if __name__ == "__main__":
    main()
